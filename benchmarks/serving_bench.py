"""Serving-path benchmark: paged decode throughput + tier-policy hit rates under a
prefix-reuse workload (the paper's KV-store use case on real model traffic)."""

from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.configs import get_config
from repro.core import emucxl as ecxl
from repro.core.policy import Policy1, Policy2
from repro.models import transformer as tf
from repro.serving.engine import ServingEngine


def bench() -> List[str]:
    out = []
    cfg = get_config("gemma3-1b").reduced()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    for policy, name in ((Policy1(), "policy1"), (Policy2(), "policy2")):
        lib = ecxl.EmuCXL()
        lib.init(local_capacity=1 << 26, remote_capacity=1 << 28)
        eng = ServingEngine(params, cfg, num_slots=4, page_size=8, max_batch=2,
                            max_pages_per_seq=2, policy=policy)
        eng.pool.lib = lib
        eng.pool.slab.lib = lib
        for _ in range(4):
            eng.submit(list(rng.integers(0, cfg.vocab_size, 5)), max_new_tokens=6)
        t0 = time.perf_counter()
        results = eng.run(max_steps=400)
        dt = time.perf_counter() - t0
        n_tokens = sum(len(v) for v in results.values())
        stats = eng.tier_stats()
        out.append(
            f"serving_decode_{name},{1e6*dt/max(n_tokens,1):.0f},"
            f"tokens={n_tokens},pct_local={stats['percent_local']:.1f}%,"
            f"preemptions={stats['preemptions']},"
            f"remote_bytes={stats['remote_bytes']}"
        )
        lib.exit()
    return out
