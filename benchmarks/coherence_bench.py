"""Coherent shared segments: the shared-prefix KV scenario + false sharing.

Two experiments over core/coherence.py:

1. **Shared common-prefix KV** (the serving scenario): N hosts all serve
   prompts with one common prefix. Baseline keeps a private cold copy of the
   prefix KV pages per host (slab-allocated in the pool, re-DMA'd on every new
   sequence); the shared variant publishes ONE coherent segment that every
   host imports through its own mapping — first import misses (page fetches on
   the fabric), steady-state imports hit the host's cached copy. Asserted:
   strictly less pool memory at >= 2 hosts, coherence traffic visible on the
   fabric links, and a modeled steady-state speedup > 1.

2. **False sharing, eager vs fenced**: N hosts alternately write small
   disjoint regions that land in the SAME coherence page vs in different
   pages. Same bytes written; the same-page eager variant ping-pongs M
   ownership (writeback + invalidation + refetch per write — an invalidation
   storm) while the split variant settles into silent M hits. The third
   variant replays the same-page storm on a ``consistency="release"`` segment:
   every host's writes land in its write-combining buffer and one ``fence()``
   per host publishes them — asserted to emit strictly fewer protocol
   messages than eager MESI-lite at >= 2 hosts.

3. **Write-combining capacity sweep**: the same cross-host write stream
   replayed over release segments with ``wc_capacity`` in {1, 4, 16, 64, ∞}.
   A bounded buffer force-drains its LRU pending page when full, so protocol
   messages fall monotonically as the buffer deepens — the eager↔fenced trade
   is a *continuous spectrum*, not a cliff: asserted that ``wc_capacity=1``
   lands within 10% of eager MESI-lite's message count and that the unbounded
   end does no forced drains (today's fenced counts).

4. **Fence scheduling** (``bench_fence_epochs``): N hosts' fences submitted
   in ONE async batch drain concurrently instead of serially (asserted
   makespan <= the serial sync-fence sum); independent fenced streams
   scheduled by the discrete-event engine finish strictly sooner than under
   the retired global-barrier wave scheduler (reconstructed as sequential
   flushes split at the fence boundary); and a fence-free batch's makespan is
   bit-identical to the begin-all-then-drain schedule it has always had.

5. **Preflight overhead** (``bench_preflight_overhead``): the same clean
   fenced batch flushed with the plan-time symbolic verifier on
   (``preflight="warn"``) vs off, interleaved and median-timed. Asserted:
   warn-mode preflight adds less than 10% to flush wall-time — the price of
   always-on batch diagnostics.

``--json PATH`` dumps the headline numbers (bytes shared vs copied,
invalidation counts, modeled speedup, eager-vs-fenced message counts, the
capacity sweep, engine-vs-wave and epoch-vs-serial fence makespans) for the
CI artifact; ``--smoke`` runs a seconds-scale configuration and enforces the
acceptance asserts.

CSV columns: name,us_per_call,derived — consistent with benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import json
import statistics
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import emucxl as ecxl
from repro.core.api import CXLSession, FenceOp, WriteOp
from repro.core.fabric import Fabric
from repro.core.policy import SharingAwarePlacement
from repro.serving.kv_manager import PagedKVPool, SharedPrefixKV

# Tiny KV geometry: one KV page = 2 * L * page * K * hd * 4B = 4 KiB.
_GEOM = dict(num_layers=2, page_size=8, kv_heads=2, head_dim=16)
_KV_PAGE_BYTES = 2 * 2 * 8 * 2 * 16 * 4


def _modeled(sess: CXLSession) -> float:
    return sum(sess.modeled_time.values())


def _fill_and_demote_prefix(pool: PagedKVPool, seq_id: int, pages: int) -> None:
    for p in range(pages):
        pool.alloc_page(seq_id, p)
    for p in range(pages):
        pool.demote(seq_id, p)


def bench_shared_prefix(num_hosts: int, prefix_pages: int = 4,
                        rounds: int = 3) -> Dict[str, object]:
    """Per-host private prefix copies vs one coherent shared segment."""
    prefix_bytes = prefix_pages * _KV_PAGE_BYTES

    # ---- baseline: every host keeps (and re-DMAs) its own pooled copy
    with CXLSession(1 << 24, 1 << 26, num_hosts=num_hosts,
                    fabric=Fabric(num_hosts=num_hosts, pool_ports=2)) as sess:
        pools = [PagedKVPool(num_slots=prefix_pages * 2, host=h, session=sess,
                             **_GEOM) for h in range(num_hosts)]
        for h, pool in enumerate(pools):
            _fill_and_demote_prefix(pool, seq_id=0, pages=prefix_pages)
        bytes_copied = sess.stats(ecxl.REMOTE_MEMORY)
        t0 = _modeled(sess)
        for _ in range(rounds):
            for pool in pools:
                # a new sequence arrives on each host: promote the private
                # copy back to HBM, then give the slots back
                for p in range(prefix_pages):
                    pool.promote(0, p)
                for p in range(prefix_pages):
                    pool.demote(0, p)
        baseline_time = _modeled(sess) - t0

    # ---- shared: one coherent segment, every host imports through it
    with CXLSession(1 << 24, 1 << 26, num_hosts=num_hosts,
                    fabric=Fabric(num_hosts=num_hosts, pool_ports=2),
                    placement=SharingAwarePlacement()) as sess:
        shared = SharedPrefixKV(sess, num_pages=prefix_pages, home_host=0,
                                **_GEOM)
        pools = [PagedKVPool(num_slots=prefix_pages * 2, host=h, session=sess,
                             **_GEOM) for h in range(num_hosts)]
        for pool in pools:
            pool.attach_shared_prefix(shared)
        # host 0 prefills the prefix hot and publishes it once
        publisher = pools[0]
        for p in range(prefix_pages):
            publisher.alloc_page(0, p)
        shared.publish(publisher, seq_id=0)
        publisher.free_sequence(0)
        bytes_shared = sess.stats(ecxl.REMOTE_MEMORY)
        t0 = _modeled(sess)
        seq = 1
        for _ in range(rounds):
            for pool in pools:
                pool.import_prefix(seq)      # miss once, then cache hits
                pool.free_sequence(seq)
                seq += 1
        shared_time = _modeled(sess) - t0
        coh = sess.coherence_stats()["total"]
        fabric_stats = sess.fabric_stats()
        # a prefix update back-invalidates every host caching the pages
        inval_before = coh["invalidations"]
        shared.update(np.zeros(_KV_PAGE_BYTES, np.uint8), page_idx=0)
        inval_after = sess.coherence_stats()["total"]["invalidations"]

    coherence_link_bytes = {
        name: s["bytes_carried"] for name, s in fabric_stats.items()
        if s["bytes_carried"] > 0
    }
    return {
        "num_hosts": num_hosts,
        "prefix_bytes": prefix_bytes,
        "bytes_copied": int(bytes_copied),
        "bytes_shared": int(bytes_shared),
        "bytes_saved": int(bytes_copied - bytes_shared),
        "baseline_time_s": baseline_time,
        "shared_time_s": shared_time,
        "modeled_speedup": (baseline_time / shared_time
                            if shared_time > 0 else float("inf")),
        "read_hits": int(coh["read_hits"]),
        "read_misses": int(coh["read_misses"]),
        "forwards": int(coh["forwards"]),
        "invalidations_on_update": int(inval_after - inval_before),
        "coherence_link_bytes": coherence_link_bytes,
    }


def bench_false_sharing(writes_per_host: int = 16,
                        num_hosts: int = 2) -> Dict[str, object]:
    """N hosts alternately writing 64B regions: same page (eager), split
    pages (eager), and same page under release-consistency write-combining."""
    results = {}
    for variant, page_stride, consistency in (
        ("same_page", 0, "eager"),           # all hosts land in page 0
        ("split_pages", 4096, "eager"),      # one page per host
        ("same_page_fenced", 0, "release"),  # the storm, write-combined
    ):
        with CXLSession(1 << 22, 1 << 24, num_hosts=num_hosts,
                        fabric=Fabric(num_hosts=num_hosts, pool_ports=1)) as sess:
            seg = sess.share(num_hosts * 4096, host=0, page_bytes=4096,
                             consistency=consistency)
            bufs = [sess.attach(seg, host=h) for h in range(num_hosts)]
            payload = np.arange(64, dtype=np.uint8)
            t0 = _modeled(sess)
            for _ in range(writes_per_host):
                for h, buf in enumerate(bufs):
                    buf.write(payload, offset=h * (page_stride or 64))
            for buf in bufs:
                buf.fence()                  # no-op on eager segments
            stats = seg.stats
            results[variant] = {
                "modeled_time_s": _modeled(sess) - t0,
                "invalidations": stats.invalidations,
                "writebacks": stats.writebacks,
                "wc_writes": stats.wc_writes,
                "fences": stats.fences,
                "protocol_msgs": (stats.invalidations + stats.writebacks
                                  + stats.forwards),
            }
    same, split = results["same_page"], results["split_pages"]
    fenced = results["same_page_fenced"]
    return {
        "writes_per_host": writes_per_host,
        "num_hosts": num_hosts,
        "same_page": same,
        "split_pages": split,
        "same_page_fenced": fenced,
        "storm_ratio": (same["modeled_time_s"] / split["modeled_time_s"]
                        if split["modeled_time_s"] > 0 else float("inf")),
        "combining_ratio": (same["protocol_msgs"] / fenced["protocol_msgs"]
                            if fenced["protocol_msgs"] > 0 else float("inf")),
    }


def _protocol_msgs(stats) -> int:
    return stats.invalidations + stats.writebacks + stats.forwards


def bench_capacity_sweep(num_hosts: int = 2, pages: int = 80, rounds: int = 3,
                         capacities=(1, 4, 16, 64, None)
                         ) -> Dict[str, object]:
    # pages exceeds the largest finite capacity so EVERY sweep point binds:
    # with pages <= 64 the {64, None} ends would measure the same config.
    """One cross-host write stream, replayed per write-combining capacity.

    Each round, every host sweeps all pages in turn (host-major passes — the
    migratory sharing pattern), then everyone fences once at the end. Deep
    buffers absorb a whole pass and publish it in one fence burst; shallow
    buffers force-drain pending pages mid-pass, and each early upgrade steals
    M from the previous pass's owner — sliding the message count continuously
    up toward eager MESI-lite as the capacity shrinks to 1."""
    def run(consistency: str, wc_capacity: Optional[int]) -> Dict[str, int]:
        with CXLSession(1 << 22, 1 << 24, num_hosts=num_hosts,
                        fabric=Fabric(num_hosts=num_hosts,
                                      pool_ports=1)) as sess:
            seg = sess.share(pages * 4096, host=0, page_bytes=4096,
                             consistency=consistency, wc_capacity=wc_capacity)
            bufs = [sess.attach(seg, host=h) for h in range(num_hosts)]
            payload = np.arange(64, dtype=np.uint8)
            for _ in range(rounds):
                for buf in bufs:
                    for p in range(pages):
                        buf.write(payload, offset=p * 4096)
            for buf in bufs:
                buf.fence()
            s = seg.stats
            return {
                "protocol_msgs": _protocol_msgs(s),
                "invalidations": s.invalidations,
                "writebacks": s.writebacks,
                "forced_drains": s.forced_drains,
                "forced_drain_pages": s.forced_drain_pages,
                "wc_writes": s.wc_writes,
                "fences": s.fences,
            }

    eager = run("eager", None)
    sweep = [dict(wc_capacity=cap, **run("release", cap))
             for cap in capacities]
    return {
        "num_hosts": num_hosts,
        "pages": pages,
        "rounds": rounds,
        "eager_protocol_msgs": eager["protocol_msgs"],
        "sweep": sweep,
    }


def bench_fence_epochs(num_hosts: int = 2, pages: int = 8
                       ) -> Dict[str, object]:
    """Fence scheduling on the discrete-event engine, three ways.

    1. **Overlapped fences**: all hosts' fences in one async batch vs the
       serial sync-fence sum (the original epoch experiment — unchanged).
    2. **Independent streams**: fenced chains (write -> fence -> post-fence
       write) on their own segments, plus one bulk unfenced stream. The
       engine's per-stream dependency graph lets each chain's post-fence
       write begin the instant its *own* fence drains; the retired wave
       scheduler's global barrier is reconstructed by splitting the batch at
       the fence boundary into sequential flushes, which stalls every
       post-fence write behind the bulk stream's wave-0 traffic. Asserted
       strictly faster at >= 2 streams.
    3. **Fence-free bit-identity**: a batch with no fences must reproduce the
       pre-engine schedule exactly — every transfer begun at the same instant,
       one drain — so its makespan is compared ``==`` (not approx) against a
       twin fabric evolving the same routes by hand.
    """
    def prepared():
        sess = CXLSession(1 << 22, 1 << 24, num_hosts=num_hosts,
                          fabric=Fabric(num_hosts=num_hosts, pool_ports=1))
        seg = sess.share(pages * 4096, host=0, page_bytes=4096,
                         consistency="release", wc_capacity=None)
        bufs = [sess.attach(seg, host=h) for h in range(num_hosts)]
        payload = np.arange(64, dtype=np.uint8)
        for buf in bufs:
            for p in range(pages):
                buf.write(payload, offset=p * 4096)
        return sess, bufs

    sess, bufs = prepared()
    with sess:
        sess.submit(*[FenceOp(buf) for buf in bufs])
        overlapped = sess.flush()
    sess, bufs = prepared()
    with sess:
        serial = sum(buf.fence() for buf in bufs)

    streams = bench_independent_streams(num_streams=max(num_hosts, 2))
    nofence = bench_nofence_bitidentity(num_hosts=max(num_hosts, 2))
    return {
        "num_hosts": num_hosts,
        "pages": pages,
        "epoch_makespan_s": overlapped,
        "serial_fence_s": serial,
        "overlap_speedup": serial / overlapped if overlapped > 0 else 1.0,
        "independent_streams": streams,
        "nofence_bitidentity": nofence,
    }


def bench_independent_streams(num_streams: int = 2,
                              bulk_bytes: int = 1 << 16
                              ) -> Dict[str, object]:
    """Per-stream dependency graph vs the retired global-barrier wave
    scheduler, on identical op batches.

    `num_streams` fenced chains — each on its own (segment, host) stream:
    buffered write, release fence, post-fence write — run alongside one bulk
    unfenced write on a further host. The wave baseline is reconstructed
    faithfully: the batch is split at the fence boundary and flushed
    sequentially, which is exactly what the old scheduler's global
    ``fabric.drain()`` between waves did to the modeled clock."""
    num_hosts = num_streams + 1
    payload = np.arange(64, dtype=np.uint8)
    bulk = np.zeros(bulk_bytes, np.uint8)

    def setup():
        sess = CXLSession(1 << 22, 1 << 26, num_hosts=num_hosts,
                          fabric=Fabric(num_hosts=num_hosts, pool_ports=2))
        chains = []
        for h in range(num_streams):
            seg = sess.share(2 * 4096, host=h, page_bytes=4096,
                             consistency="release", wc_capacity=None)
            chains.append(sess.attach(seg, host=h))
        bulk_buf = sess.alloc(bulk_bytes, ecxl.REMOTE_MEMORY,
                              host=num_streams)
        return sess, chains, bulk_buf

    def wave0_ops(chains, bulk_buf):
        ops = []
        for buf in chains:
            ops.append(WriteOp(buf, payload))
            ops.append(FenceOp(buf))
        ops.append(WriteOp(bulk_buf, bulk))
        return ops

    def wave1_ops(chains):
        return [WriteOp(buf, payload, offset=4096) for buf in chains]

    # engine: one batch, dependencies per stream
    sess, chains, bulk_buf = setup()
    with sess:
        sess.submit(*wave0_ops(chains, bulk_buf))
        sess.submit(*wave1_ops(chains))
        engine_makespan = sess.flush()
    # wave baseline: global barrier == sequential flushes at the fence cut
    sess, chains, bulk_buf = setup()
    with sess:
        sess.submit(*wave0_ops(chains, bulk_buf))
        wave_makespan = sess.flush()
        sess.submit(*wave1_ops(chains))
        wave_makespan += sess.flush()
    return {
        "num_streams": num_streams,
        "bulk_bytes": bulk_bytes,
        "engine_makespan_s": engine_makespan,
        "wave_makespan_s": wave_makespan,
        "stream_speedup": (wave_makespan / engine_makespan
                           if engine_makespan > 0 else 1.0),
    }


def bench_nofence_bitidentity(num_hosts: int = 2, nbytes: int = 1 << 18
                              ) -> Dict[str, object]:
    """A fence-free batch's modeled times must be *bit-identical* to the
    pre-engine schedule: all transfers begun at one instant, one drain.

    The reference is a twin fabric fed the same pooled-DMA routes by hand —
    exactly what the old flush did for a batch with no fences."""
    data = np.zeros(nbytes, np.uint8)

    def setup():
        fab = Fabric(num_hosts=num_hosts, pool_ports=2)
        sess = CXLSession(1 << 22, 1 << 26, num_hosts=num_hosts, fabric=fab)
        bufs = [sess.alloc(nbytes, ecxl.REMOTE_MEMORY, host=h)
                for h in range(num_hosts)]
        return fab, sess, bufs

    fab_a, sess_a, bufs_a = setup()
    with sess_a:
        sess_a.submit(*[WriteOp(b, data) for b in bufs_a])
        flush_makespan = sess_a.flush()
    fab_b, sess_b, bufs_b = setup()
    with sess_b:
        start = fab_b.clock
        for b in bufs_b:
            rec = sess_b.lib._resolve(b.address)
            fab_b.begin(fab_b.pool_path(rec.host, rec.port), nbytes)
        fab_b.drain()
        manual_makespan = fab_b.clock - start
    return {
        "num_hosts": num_hosts,
        "nbytes": nbytes,
        "flush_makespan_s": flush_makespan,
        "manual_makespan_s": manual_makespan,
        "bit_identical": flush_makespan == manual_makespan,
    }


def bench_preflight_overhead(num_hosts: int = 2, pages: int = 32,
                             rounds: int = 20) -> Dict[str, object]:
    """Wall-time cost of ``flush(preflight="warn")`` vs ``"off"`` on a clean
    fenced batch (per-host disjoint page writes + one fence each — the shape
    production flushes take). Measurements interleave and the ratio uses
    medians, so scheduler noise hits both modes alike."""
    per_host = pages // num_hosts
    payload = np.arange(4096, dtype=np.uint8) % 251

    sess = CXLSession(1 << 22, 1 << 26, num_hosts=num_hosts)
    with sess:
        seg = sess.share(pages * 4096, host=0, consistency="release",
                         race_detect="off")
        bufs = [sess.attach(seg, host=h) for h in range(num_hosts)]

        def one_flush(mode: str) -> float:
            for h, buf in enumerate(bufs):
                for p in range(per_host):
                    sess.submit(WriteOp(buf, payload,
                                        offset=(h * per_host + p) * 4096))
                sess.submit(FenceOp(buf))
            t0 = time.perf_counter()
            sess.flush(preflight=mode)
            return time.perf_counter() - t0

        one_flush("off")                           # warm both paths
        one_flush("warn")
        times: Dict[str, List[float]] = {"off": [], "warn": []}
        for _ in range(rounds):
            times["off"].append(one_flush("off"))
            times["warn"].append(one_flush("warn"))
        pf = sess.coherence_stats()["preflight"]

    off_s = statistics.median(times["off"])
    warn_s = statistics.median(times["warn"])
    return {
        "num_hosts": num_hosts,
        "ops_per_flush": num_hosts * (per_host + 1),
        "rounds": rounds,
        "off_flush_s": off_s,
        "warn_flush_s": warn_s,
        "overhead": warn_s / off_s - 1.0,
        "preflight_batches": pf["totals"]["batches"],
        "preflight_must": pf["totals"]["must"],
    }


def bench(hosts=(2, 4), prefix_pages: int = 4, rounds: int = 3,
          writes_per_host: int = 16, check: bool = False
          ) -> tuple[List[str], Dict[str, object]]:
    """Returns (CSV rows, JSON-able artifact payload)."""
    rows: List[str] = []
    artifact: Dict[str, object] = {"shared_prefix": [], "false_sharing": None}
    for n in hosts:
        r = bench_shared_prefix(n, prefix_pages, rounds)
        artifact["shared_prefix"].append(r)
        rows.append(
            f"coherence_shared_prefix_h{n},0,"
            f"bytes_shared={r['bytes_shared']},bytes_copied={r['bytes_copied']},"
            f"speedup={r['modeled_speedup']:.2f}x,"
            f"read_hits={r['read_hits']},read_misses={r['read_misses']},"
            f"invalidations_on_update={r['invalidations_on_update']}"
        )
        if check and n >= 2:
            assert r["bytes_shared"] < r["bytes_copied"], (
                f"shared prefix must use strictly less pool memory at {n} "
                f"hosts ({r['bytes_shared']} vs {r['bytes_copied']})"
            )
            assert r["modeled_speedup"] > 1.0, (
                f"steady-state imports must beat per-host re-DMA "
                f"({r['modeled_speedup']:.2f}x)"
            )
            assert r["coherence_link_bytes"], "no coherence traffic on fabric"
            assert r["invalidations_on_update"] >= n - 1, (
                "a prefix update must back-invalidate the caching hosts"
            )
    artifact["false_sharing"] = []
    for n in hosts:
        fs = bench_false_sharing(writes_per_host, num_hosts=n)
        artifact["false_sharing"].append(fs)
        rows.append(
            f"coherence_false_sharing_h{n},0,"
            f"storm_ratio={fs['storm_ratio']:.2f}x,"
            f"combining_ratio={fs['combining_ratio']:.2f}x,"
            f"same_page_msgs={fs['same_page']['protocol_msgs']},"
            f"fenced_msgs={fs['same_page_fenced']['protocol_msgs']},"
            f"split_invals={fs['split_pages']['invalidations']}"
        )
        if check:
            assert (fs["same_page"]["invalidations"]
                    > fs["split_pages"]["invalidations"]), (
                "false sharing must produce an invalidation storm"
            )
            assert fs["storm_ratio"] > 1.0
            if n >= 2:
                assert (fs["same_page_fenced"]["protocol_msgs"]
                        < fs["same_page"]["protocol_msgs"]), (
                    f"write-combining must emit fewer protocol messages than "
                    f"eager MESI-lite at {n} hosts "
                    f"({fs['same_page_fenced']['protocol_msgs']} vs "
                    f"{fs['same_page']['protocol_msgs']})"
                )
                assert fs["combining_ratio"] > 1.0
    cs = bench_capacity_sweep(num_hosts=max(hosts), rounds=rounds)
    artifact["capacity_sweep"] = cs
    sweep_summary = ";".join(
        f"cap{'inf' if r['wc_capacity'] is None else r['wc_capacity']}="
        f"{r['protocol_msgs']}" for r in cs["sweep"])
    rows.append(
        f"coherence_capacity_sweep_h{cs['num_hosts']},0,"
        f"eager_msgs={cs['eager_protocol_msgs']},{sweep_summary}"
    )
    fe = bench_fence_epochs(num_hosts=max(hosts))
    artifact["fence_epochs"] = fe
    streams = fe["independent_streams"]
    nofence = fe["nofence_bitidentity"]
    rows.append(
        f"coherence_fence_epochs_h{fe['num_hosts']},0,"
        f"epoch_makespan_s={fe['epoch_makespan_s']:.3e},"
        f"serial_fence_s={fe['serial_fence_s']:.3e},"
        f"overlap_speedup={fe['overlap_speedup']:.2f}x"
    )
    rows.append(
        f"coherence_independent_streams_s{streams['num_streams']},0,"
        f"engine_makespan_s={streams['engine_makespan_s']:.3e},"
        f"wave_makespan_s={streams['wave_makespan_s']:.3e},"
        f"stream_speedup={streams['stream_speedup']:.2f}x"
    )
    rows.append(
        f"coherence_nofence_bitidentity_h{nofence['num_hosts']},0,"
        f"flush_makespan_s={nofence['flush_makespan_s']:.9e},"
        f"manual_makespan_s={nofence['manual_makespan_s']:.9e},"
        f"bit_identical={nofence['bit_identical']}"
    )
    po = bench_preflight_overhead(num_hosts=max(hosts))
    artifact["preflight_overhead"] = po
    rows.append(
        f"coherence_preflight_overhead_h{po['num_hosts']},"
        f"{po['warn_flush_s'] * 1e6:.1f},"
        f"off_flush_s={po['off_flush_s']:.3e},"
        f"warn_flush_s={po['warn_flush_s']:.3e},"
        f"overhead={po['overhead']:.1%},"
        f"ops_per_flush={po['ops_per_flush']}"
    )
    if check:
        msgs = [r["protocol_msgs"] for r in cs["sweep"]]
        for shallow, deep in zip(msgs, msgs[1:], strict=False):
            # monotone within 5% tolerance: deepening the WC buffer must not
            # meaningfully increase protocol traffic
            assert deep <= shallow * 1.05, (
                f"capacity sweep not monotone: {msgs} "
                f"(eager={cs['eager_protocol_msgs']})"
            )
        assert msgs[-1] < msgs[0], (
            f"deepening the buffer must shed protocol traffic: {msgs}"
        )
        cap1 = cs["sweep"][0]
        assert cap1["wc_capacity"] == 1
        assert (abs(cap1["protocol_msgs"] - cs["eager_protocol_msgs"])
                <= 0.10 * cs["eager_protocol_msgs"]), (
            f"wc_capacity=1 must land within 10% of eager message counts "
            f"({cap1['protocol_msgs']} vs {cs['eager_protocol_msgs']})"
        )
        unbounded = cs["sweep"][-1]
        assert unbounded["wc_capacity"] is None
        assert unbounded["forced_drains"] == 0, (
            "an unbounded buffer must never force-drain (legacy fenced "
            "behavior)"
        )
        assert fe["epoch_makespan_s"] <= fe["serial_fence_s"] * (1 + 1e-9), (
            f"epoch-scheduled fences must not cost more than serial fencing "
            f"({fe['epoch_makespan_s']} vs {fe['serial_fence_s']})"
        )
        assert streams["num_streams"] >= 2
        assert streams["engine_makespan_s"] < streams["wave_makespan_s"], (
            f"per-stream dependency scheduling must beat the global-barrier "
            f"wave baseline at {streams['num_streams']} streams "
            f"({streams['engine_makespan_s']} vs {streams['wave_makespan_s']})"
        )
        assert nofence["bit_identical"], (
            f"a fence-free batch must reproduce the pre-engine modeled time "
            f"bit for bit ({nofence['flush_makespan_s']!r} vs "
            f"{nofence['manual_makespan_s']!r})"
        )
        assert po["preflight_must"] == 0, (
            "the overhead batch is fully fenced — preflight must find no "
            "guaranteed defect in it"
        )
        assert po["overhead"] < 0.10, (
            f"warn-mode preflight must add <10% to flush wall-time, "
            f"measured {po['overhead']:.1%} "
            f"({po['warn_flush_s']:.3e}s vs {po['off_flush_s']:.3e}s)"
        )
    return rows, artifact


SMOKE = dict(hosts=(2, 4), prefix_pages=2, rounds=2, writes_per_host=8,
             check=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast configuration for CI (asserts acceptance)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the artifact payload (bytes shared vs copied, "
                         "invalidations, speedup) as JSON")
    args = ap.parse_args()
    rows, artifact = bench(**SMOKE) if args.smoke else bench(check=True)
    print("name,us_per_call,derived")
    print("\n".join(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)


if __name__ == "__main__":
    main()
