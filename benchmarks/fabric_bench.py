"""Multi-host pooling benchmark: naive vs congestion-aware placement on the fabric.

Scenario (the CXL-3.0 scenario the single-host paper cannot express): N emulated
hosts concurrently demote cold KV-sized pages into one shared memory pool reached
through a switch with P pool ports. Naive placement (`StaticPlacement`) pins every
pooled allocation to port 0 — the degenerate single-device pooling you get with no
placement logic — so all N hosts' demotion streams serialize on one link.
Congestion-aware placement (`CongestionAwarePlacement`) picks the least-occupied
port at allocation time, spreading concurrent streams across ports.

Reported modeled throughput = total demoted bytes / fabric makespan, both derived
from the contention model in ``core/fabric.py``; per-link occupancy statistics come
from the ``emucxl`` stats API (``fabric_stats``). Expected shape: parity at 1 host
(host uplink is the bottleneck either way), congestion-aware pulling ahead as hosts
exceed one port's worth of traffic, ~P x at N >= P hosts.

CSV columns: name,us_per_call,derived — consistent with benchmarks/run.py.
"""

from __future__ import annotations

import argparse
from typing import Dict, List

from repro.core.api import CXLSession
from repro.core.emucxl import LOCAL_MEMORY, REMOTE_MEMORY
from repro.core.fabric import Fabric
from repro.core.policy import CongestionAwarePlacement, StaticPlacement
from repro.core.queue import MigrateOp

POOL_PORTS = 4


def run_pooling_experiment(
    num_hosts: int,
    placement_name: str,
    pages_per_host: int = 16,
    page_bytes: int = 2 * 1024 * 1024,
    pool_ports: int = POOL_PORTS,
) -> Dict[str, object]:
    """All hosts demote `pages_per_host` pages concurrently; returns modeled stats."""
    placement = (CongestionAwarePlacement() if placement_name == "congestion-aware"
                 else StaticPlacement())
    fabric = Fabric(num_hosts=num_hosts, pool_ports=pool_ports)
    # v2: the placement policy is injected at session construction, and the
    # concurrent burst is one async batch — submit every demote, flush once.
    with CXLSession(
        local_capacity=2 * pages_per_host * page_bytes,
        remote_capacity=2 * num_hosts * pages_per_host * page_bytes,
        num_hosts=num_hosts,
        fabric=fabric,
        placement=placement,
    ) as sess:
        tickets = [
            sess.submit(MigrateOp(sess.alloc(page_bytes, LOCAL_MEMORY, host),
                                  REMOTE_MEMORY))
            for host in range(num_hosts)
            for _ in range(pages_per_host)
        ]
        makespan = sess.flush()
        assert all(not t.result().is_local for t in tickets)
        total_bytes = num_hosts * pages_per_host * page_bytes
        link_stats = sess.fabric_stats()
        return {
            "num_hosts": num_hosts,
            "placement": placement.name,
            "total_bytes": total_bytes,
            "makespan_s": makespan,
            "throughput_gbps": total_bytes / makespan / 1e9,
            "links": link_stats,
            "ports_used": sum(
                1 for name, s in link_stats.items()
                if name.startswith("pool") and s["transfers"] > 0
            ),
        }


def bench(hosts: List[int] = (1, 2, 4, 8), pages_per_host: int = 16,
          page_bytes: int = 2 * 1024 * 1024) -> List[str]:
    rows = []
    for n in hosts:
        results = {
            name: run_pooling_experiment(n, name, pages_per_host, page_bytes)
            for name in ("static", "congestion-aware")
        }
        naive, aware = results["static"], results["congestion-aware"]
        speedup = aware["throughput_gbps"] / naive["throughput_gbps"]
        for r in (naive, aware):
            pool_busy = {
                name: round(s["busy_time"] * 1e6, 1)
                for name, s in sorted(r["links"].items())
                if name.startswith("pool")
            }
            rows.append(
                f"fabric_pooling_h{n}_{r['placement']},"
                f"{1e6 * r['makespan_s'] / (n * pages_per_host):.2f},"
                f"throughput_gbps={r['throughput_gbps']:.2f},"
                f"ports_used={r['ports_used']},"
                f"pool_busy_us={pool_busy}"
            )
        rows.append(
            f"fabric_pooling_h{n}_speedup,0,"
            f"aware_over_naive={speedup:.2f}x"
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast configuration for CI")
    ap.add_argument("--hosts", default=None,
                    help="comma-separated host counts (default 1,2,4,8)")
    args = ap.parse_args()
    hosts = [1, 4] if args.smoke else [1, 2, 4, 8]
    if args.hosts is not None:
        hosts = [int(h) for h in args.hosts.split(",")]
    pages = 4 if args.smoke else 16
    page_bytes = 256 * 1024 if args.smoke else 2 * 1024 * 1024
    print("name,us_per_call,derived")
    print("\n".join(bench(hosts, pages, page_bytes)))


if __name__ == "__main__":
    main()
