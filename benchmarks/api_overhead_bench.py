"""v2 API overhead + overlap benchmark.

Two questions about the session API (core/api.py):

1. **Dispatch overhead** — what does the handle table + session indirection cost
   per call, measured (wall clock) against the v1 ``EmuCXL`` methods it wraps,
   and what does async ``submit``+``flush`` cost per op on top of that?

2. **Overlap** (the reason v2 exists) — a batch of N >= 8 concurrent cross-host
   migrates submitted through the async queue must complete in modeled time
   *strictly less* than the sum of N serial v1 migrates on an identical
   topology, because the batch's transfers share the fabric concurrently
   instead of draining one at a time. This file asserts that property (CI runs
   it with --smoke), not just prints it.

CSV columns: name,us_per_call,derived — consistent with benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from repro.core.api import CXLSession
from repro.core.emucxl import EmuCXL, LOCAL_MEMORY, REMOTE_MEMORY
from repro.core.fabric import Fabric
from repro.core.queue import MigrateOp, ReadOp, WriteOp


# --------------------------------------------------------------------- dispatch
def bench_dispatch(n_cycles: int = 300, buf_bytes: int = 4096) -> List[str]:
    """Wall-clock us per alloc/write/read/free cycle: v1 direct vs v2 handles vs
    v2 async (submitted in batches of 16)."""
    payload = np.arange(buf_bytes, dtype=np.uint8)
    rows = []

    lib = EmuCXL()
    lib.init(local_capacity=1 << 26, remote_capacity=1 << 26)
    for _ in range(3):  # warm jit caches off the clock
        addr = lib.alloc(buf_bytes, LOCAL_MEMORY)
        lib.write(payload, 0, addr)
        lib.read(addr, 0, buf_bytes)
        lib.free(addr)
    t0 = time.perf_counter()
    for _ in range(n_cycles):
        addr = lib.alloc(buf_bytes, LOCAL_MEMORY)
        lib.write(payload, 0, addr)
        lib.read(addr, 0, buf_bytes)
        lib.free(addr)
    v1_us = 1e6 * (time.perf_counter() - t0) / n_cycles
    lib.exit()
    rows.append(f"api_dispatch_v1,{v1_us:.2f},ops=alloc+write+read+free")

    with CXLSession(1 << 26, 1 << 26) as sess:
        for _ in range(3):
            buf = sess.alloc(buf_bytes, LOCAL_MEMORY)
            buf.write(payload)
            buf.read(0, buf_bytes)
            buf.free()
        t0 = time.perf_counter()
        for _ in range(n_cycles):
            buf = sess.alloc(buf_bytes, LOCAL_MEMORY)
            buf.write(payload)
            buf.read(0, buf_bytes)
            buf.free()
        v2_us = 1e6 * (time.perf_counter() - t0) / n_cycles
        rows.append(
            f"api_dispatch_v2,{v2_us:.2f},"
            f"ops=alloc+write+read+free,overhead_vs_v1={v2_us / v1_us:.2f}x"
        )

        batch = 16
        bufs = [sess.alloc(buf_bytes, LOCAL_MEMORY) for _ in range(batch)]
        t0 = time.perf_counter()
        for _ in range(max(n_cycles // batch, 1)):
            tickets = [sess.submit(WriteOp(b, payload)) for b in bufs]
            tickets += [sess.submit(ReadOp(b, 0, buf_bytes)) for b in bufs]
            sess.flush()
            assert all(t.done() for t in tickets)
        async_us = (1e6 * (time.perf_counter() - t0)
                    / (max(n_cycles // batch, 1) * 2 * batch))
        rows.append(
            f"api_submit_v2_async,{async_us:.2f},"
            f"ops=submit(write|read)+flush,batch={batch}"
        )
    return rows


# --------------------------------------------------------------------- overlap
def _ring_topology(num_hosts: int):
    return Fabric(num_hosts=num_hosts, pool_ports=1)


def bench_overlap(num_hosts: int = 8, page_bytes: int = 1 << 20) -> List[str]:
    """N concurrent cross-host migrates, async v2 batch vs serial v1 loop.

    Every host moves one local page to its ring neighbour — N transfers whose
    (src uplink, dst uplink) paths overlap pairwise. Serial v1 drains each before
    starting the next (sum of uncontended times); the v2 batch keeps all N in
    flight, so each link carries two concurrent transfers and the makespan lands
    near serial/(N/2). The assert is the PR's acceptance criterion.
    """
    # serial v1: one blocking migrate at a time on an identical fabric
    lib = EmuCXL()
    lib.init(local_capacity=4 * page_bytes, remote_capacity=1 << 24,
             num_hosts=num_hosts, fabric=_ring_topology(num_hosts))
    addrs = [lib.alloc(page_bytes, LOCAL_MEMORY, host=h) for h in range(num_hosts)]
    serial = 0.0
    for h, addr in enumerate(addrs):
        before = lib.modeled_time[REMOTE_MEMORY]
        lib.migrate(addr, LOCAL_MEMORY, (h + 1) % num_hosts)
        serial += lib.modeled_time[REMOTE_MEMORY] - before
    lib.exit()

    # async v2: the same N moves as ONE overlapped batch
    with CXLSession(4 * page_bytes, 1 << 24, num_hosts=num_hosts,
                    fabric=_ring_topology(num_hosts)) as sess:
        bufs = [sess.alloc(page_bytes, LOCAL_MEMORY, host=h)
                for h in range(num_hosts)]
        tickets = [sess.submit(MigrateOp(b, LOCAL_MEMORY, (h + 1) % num_hosts))
                   for h, b in enumerate(bufs)]
        makespan = sess.flush()
        assert all(t.result().host == (h + 1) % num_hosts
                   for h, t in enumerate(tickets))

    assert makespan < serial, (
        f"async batch of {num_hosts} migrates must beat the serial v1 sum "
        f"({makespan:.6f}s vs {serial:.6f}s)"
    )
    return [
        f"api_overlap_migrates_h{num_hosts},0,"
        f"serial_v1_us={1e6 * serial:.1f},async_v2_us={1e6 * makespan:.1f},"
        f"speedup={serial / makespan:.2f}x,strictly_less={makespan < serial}"
    ]


# One source of truth for the CI smoke configuration — used by both this file's
# --smoke flag and benchmarks/run.py's smoke dispatch. N stays at 8 so smoke
# still gates the acceptance property.
SMOKE = dict(n_cycles=50, num_hosts=8, page_bytes=256 * 1024)


def bench(n_cycles: int = 300, num_hosts: int = 8,
          page_bytes: int = 1 << 20) -> List[str]:
    return (bench_dispatch(n_cycles)
            + bench_overlap(num_hosts, page_bytes)
            + bench_overlap(max(num_hosts * 2, 16), page_bytes))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast configuration for CI (keeps N=8 overlap)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    print("\n".join(bench(**SMOKE) if args.smoke else bench()))


if __name__ == "__main__":
    main()
