"""Perf hillclimb driver: labeled roofline variants per target cell.

Each entry under VARIANTS is one hypothesis->change iteration from EXPERIMENTS.md
§Perf: a (rules, options, grad_accum) override evaluated through the same
while-loop-corrected roofline as the baseline, written to
experiments/roofline/<arch>__<shape>__<label>.json for before/after comparison.

Run: PYTHONPATH=src python -m benchmarks.hillclimb [--cell kimi] [--label l2_...]
"""

from __future__ import annotations

import dataclasses
import json
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

from repro.models.transformer import ModelOptions

from benchmarks.roofline import OUT_DIR, analyze_cell, cell_path


def _opts(**kw) -> ModelOptions:
    base = dict(attn_impl="xla", moe_impl="ep", wkv_impl="chunked",
                ssd_impl="chunked", remat="full")
    base.update(kw)
    return ModelOptions(**base)


_ANALYSIS_MOE = dict(moe_impl="ep_exact")  # flops-exact analysis accounting

# (arch, shape) -> [(label, kwargs for analyze_cell)]
VARIANTS = {
    # -------- cell 1: kimi-k2 train (paper-technique representative) ----------
    "kimi": [
        # H1: remat recompute is ~1/4 of compiled flops; dropping it trades
        # activation memory (host offload absorbs it on TPU) for compute.
        ("kimi-k2-1t-a32b", "train_4k", "l1_remat_none",
         dict(opts_override=_opts(remat="none", **_ANALYSIS_MOE))),
        # H2: capacity factor 1.25 -> 1.0 cuts expert matmul rows ~20%.
        ("kimi-k2-1t-a32b", "train_4k", "l2_capacity_1x",
         dict(opts_override=_opts(remat="full", **_ANALYSIS_MOE),
              capacity_factor=1.0)),
        # H3: both together.
        ("kimi-k2-1t-a32b", "train_4k", "l3_remat_none_cap1x",
         dict(opts_override=_opts(remat="none", **_ANALYSIS_MOE),
              capacity_factor=1.0)),
    ],
    # -------- cell 2: kimi decode (most collective-bound cell) ----------------
    "kimi_decode": [
        # H1: part of the collective term is GSPMD resharding the seq-sharded cache
        # to head sharding and back per layer; the flash-decoding layout pins the
        # computation to the cache sharding (softmax all-reduces are tiny).
        ("kimi-k2-1t-a32b", "decode_32k", "l1_flash_layout",
         dict(opts_override=_opts(remat="none", decode_flash_layout=True,
                                  **_ANALYSIS_MOE))),
        # H2: the remainder is FSDP gathering ~2 GB of expert weights per layer to
        # decode 128 tokens; TP-within-expert (ep_ff + serve_moe_eptp) moves ~MB of
        # activations instead.
        ("kimi-k2-1t-a32b", "decode_32k", "l2_ep_ff",
         dict(opts_override=_opts(remat="none", decode_flash_layout=True,
                                  moe_impl="ep_ff_exact"),
              rules_override="serve_moe_eptp")),
    ],
    # -------- cell 3: gemma3-12b long-context decode (serving / KV tiering) ---
    "gemma_decode": [
        # H1: 40/48 layers are sliding-window; ring caches cut their per-step KV
        # reads from O(context) to O(window) — the dominant memory term.
        ("gemma3-12b", "decode_32k", "l1_sliding_ring",
         dict(opts_override=_opts(remat="none", sliding_ring=True))),
        # H2: + flash layout for the remaining global-layer caches (K=8 < tp).
        ("gemma3-12b", "decode_32k", "l2_ring_flash",
         dict(opts_override=_opts(remat="none", sliding_ring=True,
                                  decode_flash_layout=True))),
        ("gemma3-12b", "long_500k", "l1_sliding_ring",
         dict(opts_override=_opts(remat="none", sliding_ring=True))),
        ("gemma3-12b", "long_500k", "l2_ring_flash",
         dict(opts_override=_opts(remat="none", sliding_ring=True,
                                  decode_flash_layout=True))),
    ],
}


def run_variant(arch, shape, label, kw) -> None:
    kw = dict(kw)
    cap = kw.pop("capacity_factor", None)
    if cap is not None:
        # capacity-factor change rides through a config patch
        import repro.configs.base as cb

        cfg = cb.get_config(arch)
        cb._REGISTRY[arch] = dataclasses.replace(cfg, moe_capacity_factor=cap)
    res = analyze_cell(arch, shape, label=label, **kw)
    if cap is not None:
        cb._REGISTRY[arch] = cfg
    if res is None:
        print(f"[hillclimb] {arch} x {shape} [{label}]: skip")
        return
    cell_path(arch, shape, label).write_text(
        json.dumps(dataclasses.asdict(res), indent=1))
    print(f"[hillclimb] {arch} x {shape} [{label}]: {res.bottleneck}-bound "
          f"frac={res.roofline_fraction:.4f} "
          f"compute={res.t_compute:.3f}s memory={res.t_memory:.3f}s "
          f"coll={res.t_collective:.3f}s host={res.t_hostdma:.3f}s")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=[*VARIANTS, None])
    ap.add_argument("--label", default=None)
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    for cell, variants in VARIANTS.items():
        if args.cell and cell != args.cell:
            continue
        for arch, shape, label, kw in variants:
            if args.label and label != args.label:
                continue
            if cell_path(arch, shape, label).exists():
                print(f"[hillclimb] {arch} x {shape} [{label}]: cached")
                continue
            run_variant(arch, shape, label, kw)


if __name__ == "__main__":
    main()
