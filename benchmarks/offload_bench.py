"""Optimizer-offload ledger: per-arch host-DMA budget for the paper's technique.

For every arch whose optimizer state is offloaded to the emulated-CXL tier, report
the per-step DMA bytes/chip, the modeled transfer time over the fabric path the
bytes actually cross (host uplink + pool port, with link/switch latencies — the
same model v2 sessions charge), and the compute time it must overlap with (the
roofline compute term) — i.e. whether the offload is FREE (hidden behind compute)
or becomes the bottleneck.
"""

from __future__ import annotations

import json
import pathlib
from typing import List

from repro.configs import ARCH_IDS, get_config
from repro.core.fabric import Fabric

ROOF_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "roofline"


def bench() -> List[str]:
    from repro.launch.dryrun import default_hp
    from repro.launch.specs import offload_manifest

    out = []
    # One chip's step traffic priced as a fabric transfer (uncontended here; a
    # v2 session sharing the fabric would see it contend with its neighbours).
    fabric = Fabric(num_hosts=1, pool_ports=1)
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        hp = default_hp(cfg)
        man = offload_manifest(cfg, hp)
        if not hp.offload_state:
            out.append(f"offload_{arch},0,offloaded=no")
            continue
        per_chip = man.dma_bytes_per_step() / 256
        t_dma = fabric.transfer(fabric.pool_path(0, 0), int(per_chip))
        t_comp = ""
        roof = ROOF_DIR / f"{arch}__train_4k__baseline.json"
        if roof.exists():
            r = json.loads(roof.read_text())
            t_comp = f",compute_s={r['t_compute']:.3f}" \
                     f",hidden={'yes' if t_dma < r['t_compute'] else 'NO'}"
        out.append(
            f"offload_{arch},0,bytes_per_chip={per_chip/2**30:.2f}GiB,"
            f"dma_s={t_dma:.3f}{t_comp}"
        )
    return out
