"""Benchmark entry point: one function per paper table + system benches.

Prints ``name,us_per_call,derived`` CSV rows. ``--full`` uses the paper's exact
workload sizes (50k GETs, 15k queue ops); default is scaled for wall-clock;
``--smoke`` is a seconds-scale CI gate that exercises every selected bench at
tiny size so the benchmark code can never silently rot.
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale workloads (slower)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast CI configuration (seconds, CPU)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: queue,policy,fabric,api,"
                         "coherence,topology,kernels,offload,serving")
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    selected = set(args.only.split(",")) if args.only else None
    smoke_capable = {"queue", "policy", "fabric", "api", "coherence",
                     "topology"}
    if args.smoke:
        if selected is None:
            # Smoke gates the pure-model benches; kernel/serving compile paths
            # have their own tier-1 tests and would dominate wall-clock here.
            selected = set(smoke_capable)
        elif selected - smoke_capable:
            ap.error(
                "--smoke has no fast path for: "
                + ",".join(sorted(selected - smoke_capable))
            )

    rows = ["name,us_per_call,derived"]

    def want(name: str) -> bool:
        return selected is None or name in selected

    if want("fabric"):
        from benchmarks import fabric_bench
        if args.smoke:
            rows += fabric_bench.bench(hosts=[1, 4], pages_per_host=4,
                                       page_bytes=256 * 1024)
        else:
            rows += fabric_bench.bench()

    if want("api"):
        from benchmarks import api_overhead_bench
        if args.smoke:
            rows += api_overhead_bench.bench(**api_overhead_bench.SMOKE)
        else:
            rows += api_overhead_bench.bench()

    if want("coherence"):
        from benchmarks import coherence_bench
        if args.smoke:
            rows += coherence_bench.bench(**coherence_bench.SMOKE)[0]
        else:
            rows += coherence_bench.bench(check=True)[0]

    if want("topology"):
        from benchmarks import topology_bench
        if args.smoke:
            rows += topology_bench.bench(**topology_bench.SMOKE)[0]
        else:
            rows += topology_bench.bench(check=True)[0]

    if want("queue"):
        from benchmarks import queue_latency
        if args.smoke:
            rows += queue_latency.bench(n_ops=100, repeats=1)
        elif args.full:
            for r in queue_latency.run_queue_experiment(15000, 3):
                for op in ("enqueue", "dequeue"):
                    rows.append(
                        f"queue_{op}_{r['tier']},"
                        f"{1e3*r[f'{op}_ms_measured_mean']/r['n_ops']:.2f},"
                        f"measured_ms={r[f'{op}_ms_measured_mean']:.1f}"
                        f"+-{r[f'{op}_ms_measured_std']:.1f},"
                        f"modeled_v5e_ms={r[f'{op}_ms_modeled_v5e']:.3f}"
                    )
        else:
            rows += queue_latency.bench()

    if want("policy"):
        from benchmarks import policy_table
        if args.smoke:
            rows += policy_table.bench(n_gets=500)
        elif args.full:
            for r in policy_table.full_table(50000):
                rows.append(
                    f"policy_table_{r['hot_frac']},0,"
                    f"p1={r['policy1_pct_local']:.2f}%,"
                    f"p2={r['policy2_pct_local']:.2f}%,diff={r['diff']:.2f},"
                    f"paper_p1={r['paper_policy1']},paper_p2={r['paper_policy2']}"
                )
        else:
            rows += policy_table.bench()

    if want("kernels"):
        from benchmarks import kernel_bench
        rows += kernel_bench.bench()

    if want("serving"):
        from benchmarks import serving_bench
        rows += serving_bench.bench()

    if want("offload"):
        from benchmarks import offload_bench
        rows += offload_bench.bench()

    print("\n".join(rows))
    sys.stdout.flush()


if __name__ == "__main__":
    main()
