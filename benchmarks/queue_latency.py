"""Paper Table III analogue: enqueue/dequeue on local vs remote tier.

The paper measures 15000 queue operations entirely in local vs entirely in remote
NUMA memory (Table III: remote enqueue ~+12.8%, remote dequeue ~+19.8%). We report:
  * measured wall time on this host (CPU runtime: both tiers are host DRAM, so the
    gap reflects API overhead only — reported for completeness);
  * MODELED v5e times from the hardware model (HBM vs PCIe-class host link), which
    is the Table III analogue for the target platform.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import emucxl as ecxl
from repro.core.emucxl import EmuCXL
from repro.core.queue import EmuQueue


def run_queue_experiment(n_ops: int = 15000, repeats: int = 3) -> List[Dict]:
    rows = []
    for node, name in ((ecxl.LOCAL_MEMORY, "local"), (ecxl.REMOTE_MEMORY, "remote")):
        enq_times, deq_times = [], []
        modeled_enq = modeled_deq = 0.0
        for _ in range(repeats):
            lib = EmuCXL()
            lib.init(local_capacity=1 << 24, remote_capacity=1 << 24)
            q = EmuQueue(policy=node, lib=lib)
            lib.modeled_time[node] = 0.0
            t0 = time.perf_counter()
            for i in range(n_ops):
                q.enqueue(i)
            enq_times.append(time.perf_counter() - t0)
            modeled_enq = lib.modeled_time[node]
            lib.modeled_time[node] = 0.0
            t0 = time.perf_counter()
            for _ in range(n_ops):
                q.dequeue()
            deq_times.append(time.perf_counter() - t0)
            modeled_deq = lib.modeled_time[node]
            lib.exit()
        rows.append({
            "tier": name,
            "enqueue_ms_measured_mean": 1e3 * float(np.mean(enq_times)),
            "enqueue_ms_measured_std": 1e3 * float(np.std(enq_times)),
            "dequeue_ms_measured_mean": 1e3 * float(np.mean(deq_times)),
            "dequeue_ms_measured_std": 1e3 * float(np.std(deq_times)),
            "enqueue_ms_modeled_v5e": 1e3 * modeled_enq,
            "dequeue_ms_modeled_v5e": 1e3 * modeled_deq,
            "n_ops": n_ops,
        })
    return rows


def bench(n_ops: int = 2000, repeats: int = 2) -> List[str]:
    rows = run_queue_experiment(n_ops=n_ops, repeats=repeats)  # scaled for CI wall time
    out = []
    for r in rows:
        per_call_us = 1e3 * r["enqueue_ms_measured_mean"] / r["n_ops"]
        out.append(
            f"queue_enqueue_{r['tier']},{per_call_us:.2f},"
            f"modeled_v5e_ms={r['enqueue_ms_modeled_v5e']:.3f}"
        )
        per_call_us = 1e3 * r["dequeue_ms_measured_mean"] / r["n_ops"]
        out.append(
            f"queue_dequeue_{r['tier']},{per_call_us:.2f},"
            f"modeled_v5e_ms={r['dequeue_ms_modeled_v5e']:.3f}"
        )
    return out
