"""Paper Table IV reproduction — EXACT experimental protocol.

Setup (paper §IV-B): local tier bounded at 300 objects, 1000 objects total, LRU
demotion. 1000 PUTs then 50000 GETs where 90% of requests target the hottest x% of
objects, x in {10..90}, plus a uniform-random row. Reported: % of GETs served from
local memory under Policy1 (optimistic promote) vs Policy2 (no movement).

Paper values for reference (Policy1 / Policy2 / diff):
  10%: 81.37 / 3.29 / 78.08     50%: 14.87 / 5.94 / 8.93     90%: 30.43/29.95/0.48
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.emucxl import EmuCXL
from repro.core.kvstore import KVStore
from repro.core.policy import Policy1, Policy2

PAPER_TABLE_IV = {
    0.10: (81.37, 3.29), 0.20: (50.95, 3.77), 0.30: (28.59, 4.28),
    0.40: (18.03, 4.94), 0.50: (14.87, 5.94), 0.60: (12.67, 7.57),
    0.70: (12.68, 10.00), 0.80: (22.22, 21.17), 0.90: (30.43, 29.95),
    "random": (29.79, 30.01),
}


def run_policy_experiment(
    hot_frac, policy, n_objects=1000, local_cap=300, n_puts=1000, n_gets=50000,
    seed=0,
) -> float:
    lib = EmuCXL()
    lib.init(local_capacity=1 << 26, remote_capacity=1 << 27)
    kv = KVStore(lib=lib, local_capacity_objects=local_cap, policy=policy)
    for i in range(n_puts):
        kv.put(f"k{i % n_objects}", f"value-{i}".encode())
    kv.stats.reset()
    g = np.random.default_rng(seed)
    # pre-draw for speed
    coins = g.random(n_gets)
    hot_n = n_objects if hot_frac == "random" else max(int(hot_frac * n_objects), 1)
    hot_ids = g.integers(0, hot_n, n_gets)
    all_ids = g.integers(0, n_objects, n_gets)
    for c, h, a in zip(coins, hot_ids, all_ids, strict=True):
        if hot_frac != "random" and c < 0.9:
            kv.get(f"k{h}")
        else:
            kv.get(f"k{a}")
    pct = kv.stats.percent_local
    lib.exit()
    return pct


def full_table(n_gets: int = 50000) -> List[Dict]:
    rows = []
    for frac in [*np.round(np.arange(0.1, 1.0, 0.1), 2), "random"]:
        p1 = run_policy_experiment(frac, Policy1(), n_gets=n_gets)
        p2 = run_policy_experiment(frac, Policy2(), n_gets=n_gets)
        key = float(frac) if frac != "random" else "random"
        paper = PAPER_TABLE_IV.get(key, (None, None))
        rows.append({
            "hot_frac": frac, "policy1_pct_local": p1, "policy2_pct_local": p2,
            "diff": p1 - p2, "paper_policy1": paper[0], "paper_policy2": paper[1],
        })
    return rows


def bench(n_gets: int = 5000) -> List[str]:
    rows = full_table(n_gets=n_gets)  # scaled for CI; run.py --full uses 50000
    out = []
    for r in rows:
        out.append(
            f"policy_table_{r['hot_frac']},0,"
            f"p1={r['policy1_pct_local']:.2f}%,p2={r['policy2_pct_local']:.2f}%,"
            f"diff={r['diff']:.2f},paper_p1={r['paper_policy1']},"
            f"paper_p2={r['paper_policy2']}"
        )
    return out
