"""Pluggable topologies: ECMP trunk balance + sharded directory homes.

Two experiments over core/topology.py routing and the per-page directory
home policies (core/policy.py):

1. **ECMP spine balance** (spine-leaf): every cross-leaf host drives one bulk
   transfer to every remote pool port through a 2-leaf x 2-spine fabric —
   16 distinct flows at the default size. Under deterministic ECMP (CRC32
   flow hash over lexicographic equal-cost paths) the four leaf-spine trunk
   ports must carry near-equal bytes; with ``ecmp=False`` every tie collapses
   onto the first candidate spine, so the other spine's trunks carry nothing.
   Asserted: ECMP max/min trunk-byte ratio <= 1.5 while the single-spine
   routing shows > 3 — the skew ECMP exists to remove. Also recorded: the
   cross-leaf drain makespan for both routings (same offered load, so the
   single-spine variant's halved trunk capacity shows up as elapsed time).

2. **Directory home sharding** (single switch): N hosts write and read a
   shared eager segment page by page. With every page homed on port 0
   (``PinnedHome(0)`` — exactly the legacy all-on-the-backing-port layout)
   the whole protocol stream — RFO fetches, invalidations, writebacks —
   funnels through one pool port; ``StripedHome()`` spreads page homes across
   every port. Asserted: sharding strictly reduces the hottest pool port's
   ``busy_time`` and carried bytes, while total protocol messages are
   unchanged (the policy moves traffic, it must not invent or lose any).

``--json PATH`` dumps the headline numbers (per-trunk bytes and ratios for
both routings, per-port busy times for both home policies) for the CI
artifact; ``--smoke`` runs a seconds-scale configuration and enforces the
acceptance asserts.

CSV columns: name,us_per_call,derived — consistent with benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core.api import CXLSession
from repro.core.fabric import Fabric
from repro.core.policy import PinnedHome, StripedHome
from repro.core.topology import TRUNK, spine_leaf

_PAGE = 4096


# ------------------------------------------------------------- ECMP balance
def bench_ecmp_balance(leaves: int = 2, spines: int = 2,
                       hosts_per_leaf: int = 4, pool_ports_per_leaf: int = 2,
                       nbytes: int = 1 << 20) -> Dict[str, object]:
    """Drive every cross-leaf (host, pool port) flow once and tally the bytes
    each leaf-spine trunk carried, under ECMP and under first-candidate
    (single-spine) routing."""
    out: Dict[str, object] = {
        "leaves": leaves, "spines": spines,
        "hosts_per_leaf": hosts_per_leaf,
        "pool_ports_per_leaf": pool_ports_per_leaf,
        "nbytes_per_flow": nbytes,
    }
    for label, ecmp in (("ecmp", True), ("single_spine", False)):
        topo = spine_leaf(leaves=leaves, spines=spines,
                          hosts_per_leaf=hosts_per_leaf,
                          pool_ports_per_leaf=pool_ports_per_leaf,
                          ecmp=ecmp)
        fab = Fabric(topology=topo)
        flows = 0
        t0 = time.perf_counter()
        for h in range(topo.num_hosts):
            for p in range(topo.pool_ports):
                path = fab.pool_path(h, p)
                if len(path) == 2:          # same leaf: no trunk crossed
                    continue
                fab.begin(path, nbytes)
                flows += 1
        makespan = fab.drain()
        wall = time.perf_counter() - t0
        stats = fab.stats()
        trunks = sorted(name for name, spec in topo.links.items()
                        if spec.kind == TRUNK)
        trunk_bytes = {t: stats[t]["bytes_carried"] for t in trunks}
        hi, lo = max(trunk_bytes.values()), min(trunk_bytes.values())
        out[label] = {
            "flows": flows,
            "trunk_bytes": trunk_bytes,
            "max_trunk_bytes": hi,
            "min_trunk_bytes": lo,
            "max_min_ratio": hi / max(lo, 1),
            "makespan_s": makespan,
            "wall_s": wall,
        }
    return out


# ------------------------------------------------------- directory sharding
def bench_directory_sharding(hosts: int = 4, pool_ports: int = 4,
                             pages: int = 16,
                             rounds: int = 2) -> Dict[str, object]:
    """Replay the same multi-host coherent write/read churn under the
    all-home-on-port-0 layout and under striped per-page homes, and compare
    where the protocol traffic lands."""
    out: Dict[str, object] = {"hosts": hosts, "pool_ports": pool_ports,
                              "pages": pages, "rounds": rounds}
    for label, home in (("pinned", PinnedHome(0)), ("striped", StripedHome())):
        with CXLSession(1 << 22, 1 << 26, num_hosts=hosts,
                        fabric=Fabric(num_hosts=hosts,
                                      pool_ports=pool_ports)) as sess:
            seg = sess.share(pages * _PAGE, host=0, page_bytes=_PAGE,
                             home=home)
            handles = [sess.attach(seg, host=h) for h in range(hosts)]
            t0 = time.perf_counter()
            for rnd in range(rounds):
                for page in range(pages):
                    writer = handles[(page + rnd) % hosts]
                    reader = handles[(page + rnd + 1) % hosts]
                    writer.write(np.full(_PAGE, (page + rnd) % 251, np.uint8),
                                 offset=page * _PAGE)
                    reader.read(page * _PAGE, _PAGE)
            wall = time.perf_counter() - t0
            fab = sess.fabric
            stats = fab.stats()
            busy = {j: stats[fab.pool_link(j)]["busy_time"]
                    for j in range(pool_ports)}
            carried = {j: stats[fab.pool_link(j)]["bytes_carried"]
                       for j in range(pool_ports)}
            tot = sess.lib.coherence_stats()["total"]
            out[label] = {
                "home": seg.describe()["home"],
                "port_busy_s": busy,
                "hottest_port_busy_s": max(busy.values()),
                "port_bytes": carried,
                "hottest_port_bytes": max(carried.values()),
                # fetches + the coherence_bench message census: the policy
                # relocates this traffic, it must not change its volume
                "protocol_msgs": (tot["read_misses"] + tot["write_misses"]
                                  + tot["invalidations"] + tot["writebacks"]
                                  + tot["forwards"]),
                "wall_s": wall,
            }
    return out


# ------------------------------------------------------------------ harness
def bench(leaves: int = 2, spines: int = 2, hosts_per_leaf: int = 4,
          pool_ports_per_leaf: int = 2, nbytes: int = 1 << 20,
          shard_hosts: int = 4, shard_ports: int = 4, pages: int = 16,
          rounds: int = 2,
          check: bool = False) -> tuple[List[str], Dict[str, object]]:
    eb = bench_ecmp_balance(leaves=leaves, spines=spines,
                            hosts_per_leaf=hosts_per_leaf,
                            pool_ports_per_leaf=pool_ports_per_leaf,
                            nbytes=nbytes)
    ds = bench_directory_sharding(hosts=shard_hosts, pool_ports=shard_ports,
                                  pages=pages, rounds=rounds)
    artifact: Dict[str, object] = {"ecmp_balance": eb,
                                   "directory_sharding": ds}
    rows: List[str] = []
    for label in ("ecmp", "single_spine"):
        r = eb[label]
        rows.append(
            f"topology_{label}_f{r['flows']},"
            f"{r['wall_s'] / max(r['flows'], 1) * 1e6:.1f},"
            f"max_trunk_bytes={r['max_trunk_bytes']},"
            f"min_trunk_bytes={r['min_trunk_bytes']},"
            f"max_min_ratio={r['max_min_ratio']:.2f},"
            f"makespan_s={r['makespan_s']:.3e}"
        )
    calls = pages * rounds * 2
    for label in ("pinned", "striped"):
        r = ds[label]
        rows.append(
            f"topology_home_{label}_h{ds['hosts']}p{ds['pool_ports']},"
            f"{r['wall_s'] / calls * 1e6:.1f},"
            f"hottest_port_busy_s={r['hottest_port_busy_s']:.3e},"
            f"hottest_port_bytes={r['hottest_port_bytes']},"
            f"protocol_msgs={r['protocol_msgs']}"
        )
    if check:
        ecmp, single = eb["ecmp"], eb["single_spine"]
        assert ecmp["flows"] == single["flows"] >= 4, (
            f"need a real cross-leaf flow population, got {ecmp['flows']}"
        )
        assert ecmp["max_min_ratio"] <= 1.5, (
            f"ECMP must balance trunk bytes to within 1.5x: "
            f"{ecmp['trunk_bytes']}"
        )
        assert single["max_min_ratio"] > 3, (
            f"first-candidate routing must visibly skew the trunks "
            f"(the imbalance ECMP exists to fix): {single['trunk_bytes']}"
        )
        assert single["makespan_s"] > ecmp["makespan_s"], (
            f"halving usable trunk capacity must cost drain time "
            f"({single['makespan_s']} vs {ecmp['makespan_s']})"
        )
        pin, stripe = ds["pinned"], ds["striped"]
        assert stripe["hottest_port_busy_s"] < pin["hottest_port_busy_s"], (
            f"striped homes must strictly drain the hottest port "
            f"({stripe['hottest_port_busy_s']} vs "
            f"{pin['hottest_port_busy_s']})"
        )
        assert stripe["hottest_port_bytes"] < pin["hottest_port_bytes"], (
            f"striped homes must strictly spread carried bytes "
            f"({stripe['port_bytes']} vs {pin['port_bytes']})"
        )
        assert stripe["protocol_msgs"] == pin["protocol_msgs"], (
            f"a home policy moves protocol traffic, it must not change its "
            f"volume ({stripe['protocol_msgs']} vs {pin['protocol_msgs']})"
        )
    return rows, artifact


SMOKE = dict(nbytes=1 << 18, pages=8, rounds=2, check=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast configuration for CI (asserts acceptance)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the artifact payload (per-trunk bytes and "
                         "ratios, per-port busy times per home policy) as "
                         "JSON")
    args = ap.parse_args()
    rows, artifact = bench(**SMOKE) if args.smoke else bench(check=True)
    print("name,us_per_call,derived")
    print("\n".join(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)


if __name__ == "__main__":
    main()
