"""Render EXPERIMENTS.md tables from the dry-run / roofline artifacts."""

from __future__ import annotations

import json
import pathlib

from repro.configs import ARCH_IDS, SHAPES

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"
ROOF = ROOT / "experiments" / "roofline"


def _gib(x) -> str:
    return f"{x / 2**30:.2f}"


def dryrun_table() -> str:
    lines = [
        "| arch | shape | mesh | rules | ga | args GiB/dev | host-tier GiB/dev | "
        "temp GiB/dev | collectives (counts) | link GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                p = DRYRUN / f"{arch}__{shape}__{mesh}.json"
                if not p.exists():
                    continue
                d = json.loads(p.read_text())
                if d["status"] == "skip":
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | — | — | — | — | — | "
                        f"SKIP: {d['skip_reason']} | — |"
                    )
                    continue
                if d["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | FAIL | | | | | {d['error'][:60]} | |")
                    continue
                mem = d["memory"]
                coll = d["collectives"]
                chips = 512 if mesh == "2x16x16" else 256
                offload = d["offload_bytes"] if shape.startswith("train") else 0
                host_gib = offload / chips / 2**30
                counts = ",".join(f"{k.split('-')[0][:2]}{k.split('-')[1][:1] if '-' in k else ''}:{v}"
                                  for k, v in sorted(coll["counts"].items()))
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {d['rules']} | {d['grad_accum']} "
                    f"| {_gib(mem['argument_bytes'] - offload/chips)} "
                    f"| {host_gib:.2f} "
                    f"| {_gib(mem['temp_bytes'])} "
                    f"| {counts} "
                    f"| {coll['link_bytes']/1e9:.2f} |"
                )
    return "\n".join(lines)


def roofline_table(label: str = "baseline") -> str:
    lines = [
        "| arch | shape | bottleneck | compute s | memory s | collective s | "
        "host-DMA s | MODEL_FLOPS | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            p = ROOF / f"{arch}__{shape}__{label}.json"
            if not p.exists():
                continue
            d = json.loads(p.read_text())
            lines.append(
                f"| {arch} | {shape} | **{d['bottleneck']}** "
                f"| {d['t_compute']:.3f} | {d['t_memory']:.3f} "
                f"| {d['t_collective']:.3f} | {d['t_hostdma']:.3f} "
                f"| {d['model_flops']:.2e} | {d['useful_ratio']:.3f} "
                f"| {d['roofline_fraction']:.4f} |"
            )
    return "\n".join(lines)


def perf_table(arch: str, shape: str) -> str:
    """Before/after rows for one hillclimbed cell (baseline + labeled variants)."""
    rows = [
        "| variant | bottleneck | compute s | memory s | collective s | host s | "
        "roofline frac | Δfrac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    base = None
    for p in sorted(ROOF.glob(f"{arch}__{shape}__*.json")):
        d = json.loads(p.read_text())
        label = p.stem.split("__")[-1]
        if label == "baseline":
            base = d
    order = ["baseline", *sorted(
        p.stem.split("__")[-1] for p in ROOF.glob(f"{arch}__{shape}__*.json")
        if not p.stem.endswith("baseline")
    )]
    for label in order:
        p = ROOF / f"{arch}__{shape}__{label}.json"
        if not p.exists():
            continue
        d = json.loads(p.read_text())
        delta = ""
        if base and label != "baseline" and base["roofline_fraction"] > 0:
            delta = f"{(d['roofline_fraction']/base['roofline_fraction']-1)*100:+.0f}%"
        rows.append(
            f"| {label} | {d['bottleneck']} | {d['t_compute']:.3f} "
            f"| {d['t_memory']:.3f} | {d['t_collective']:.3f} | {d['t_hostdma']:.3f} "
            f"| {d['roofline_fraction']:.4f} | {delta} |"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "dryrun"
    if which == "dryrun":
        print(dryrun_table())
    elif which == "perf":
        print(perf_table(sys.argv[2], sys.argv[3]))
    else:
        print(roofline_table(sys.argv[2] if len(sys.argv) > 2 else "baseline"))
