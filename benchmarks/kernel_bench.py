"""Kernel micro-benchmarks: wall time of the XLA production paths on this host +
derived GFLOP/s (the Pallas kernels are TPU-target; interpret mode timings are not
meaningful, so we bench their XLA equivalents and the ref oracles)."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_ssd.ops import ssd
from repro.kernels.rwkv6_scan.ops import wkv6
from repro.models.attention import _chunked_attention


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench() -> List[str]:
    rng = np.random.default_rng(0)
    out = []

    # chunked attention vs naive at 4k (the long-context XLA baseline)
    B, S, N, hd = 1, 2048, 4, 64
    q, k, v = (jnp.asarray(rng.standard_normal((B, S, N, hd)), jnp.float32)
               for _ in range(3))
    win = jnp.int32(1 << 30)
    naive = jax.jit(lambda q, k, v: attention_ref(q, k, v, win, scale=0.125))
    chunked = jax.jit(lambda q, k, v: _chunked_attention(
        q, k, v, window=win, causal=True, scale=0.125, q_block=256))
    t_naive = _time(naive, q, k, v)
    t_chunk = _time(chunked, q, k, v)
    flops = 2 * 2 * B * S * S * N * hd / 2
    out.append(f"attn_naive_2k,{1e6*t_naive:.0f},gflops={flops/t_naive/1e9:.1f}")
    out.append(f"attn_chunked_2k,{1e6*t_chunk:.0f},gflops={flops/t_chunk/1e9:.1f}")

    # wkv6 chunked vs ref scan
    B, T, H, K = 1, 1024, 4, 64
    r, kk, vv = (jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
                 for _ in range(3))
    w = jnp.asarray(rng.uniform(0.5, 0.999, (B, T, H, K)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, K)) * 0.1, jnp.float32)
    s0 = jnp.zeros((B, H, K, K))
    ref_fn = jax.jit(lambda *a: wkv6(*a, impl="ref"))
    chk_fn = jax.jit(lambda *a: wkv6(*a, impl="chunked"))
    t_ref = _time(ref_fn, r, kk, vv, w, u, s0)
    t_chk = _time(chk_fn, r, kk, vv, w, u, s0)
    out.append(f"wkv6_refscan_1k,{1e6*t_ref:.0f},speedup=1.0")
    out.append(f"wkv6_chunked_1k,{1e6*t_chk:.0f},speedup={t_ref/t_chk:.2f}")

    # ssd chunked vs ref scan
    P, Nst = 64, 64
    x = jnp.asarray(rng.standard_normal((B, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.5, (B, T, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, T, Nst)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((B, T, Nst)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((H,)) * 0.1, jnp.float32)
    h0 = jnp.zeros((B, H, P, Nst))
    ref_fn = jax.jit(lambda *a: ssd(*a, impl="ref"))
    chk_fn = jax.jit(lambda *a: ssd(*a, impl="chunked"))
    t_ref = _time(ref_fn, x, dt, A, Bm, C, D, h0)
    t_chk = _time(chk_fn, x, dt, A, Bm, C, D, h0)
    out.append(f"ssd_refscan_1k,{1e6*t_ref:.0f},speedup=1.0")
    out.append(f"ssd_chunked_1k,{1e6*t_chk:.0f},speedup={t_ref/t_chk:.2f}")
    return out
