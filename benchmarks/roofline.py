"""Roofline term extraction with while-loop-corrected HLO costs.

PROBLEM: XLA's HloCostAnalysis counts a while body ONCE, but production steps scan
over layers / chunks / microbatches — reported FLOPs under the scanned lowering are
~L x too small (verified empirically: scan-of-4-matmuls reports 1/4 the unrolled
flops).

METHOD: lower each cell a handful of times at small (num_layers L, seq_len T) with
EVERY lax.scan fully unrolled (ModelOptions.unroll_scans) so costs are exact, then
fit the exact polynomial structure

    cost(L, T) = L * (a + b T + c T^2) + (d + e T + f T^2)

(attention is quadratic in T; SSM/sliding layers land in the linear term; embed/
unembed/loss live in the intercept) and evaluate at the production (L, T). Six
points (2 L x 3 T) determine the six coefficients exactly; decode cells have no
T-loop in the graph, so they use a 2-point linear fit in L at the production T.
The same correction applies to bytes and collective link traffic. memory_analysis
comes from the TRUE production compile (launch/dryrun.py artifacts).

Validation: the fitted HLO FLOPs are cross-checked against analytic 6ND/2ND model
FLOPs — the MODEL_FLOPS ratio reported per cell (EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Dict, List, Optional, Tuple

# must precede any jax initialization (the analysis lowers build production meshes)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core.hw import V5E

OUT_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "roofline"
DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


# --------------------------------------------------------------------- fit points
def layer_period(cfg) -> int:
    if cfg.attention_kind == "sliding_global" and cfg.global_every:
        return cfg.global_every
    if cfg.ssm_attn_every:
        return cfg.ssm_attn_every
    return 1


def cost_degree(cfg, shape) -> int:
    """Polynomial degree of per-layer cost in T. Attention-free families are exactly
    linear (chunked scans: T/c blocks of constant work); attention families are
    exactly quadratic (causal masked scores) — so extrapolating the fitted
    polynomial from small T to production T is exact, not approximate."""
    if shape.kind == "decode":
        return 0
    if cfg.family in ("ssm", "hybrid"):
        return 1
    return 2


def analysis_points(cfg, shape) -> Tuple[List[int], List[int]]:
    """(L points, T points) for the fit, respecting pattern periods, window regime
    (T >= 2*window so sliding layers are in their linear piece), and chunk sizes.
    Points stay SMALL — the polynomial structure is exact, so small-T lowers
    (fast unrolled compiles) determine the production-T cost exactly."""
    p = layer_period(cfg)
    base = max(p, 2 if cfg.moe_first_dense else p)
    Ls = [cfg.moe_first_dense + base, cfg.moe_first_dense + 2 * base]
    if shape.kind == "decode":
        return Ls, [shape.seq_len]
    deg = cost_degree(cfg, shape)
    floor_t = 512
    if cfg.attention_kind == "sliding_global":
        floor_t = max(floor_t, 2 * cfg.sliding_window)
    t1 = max(min(floor_t, shape.seq_len), 256)
    Ts = [t1 * (1 << i) for i in range(deg + 1)]
    Ts = [min(t, shape.seq_len) for t in Ts]
    Ts = sorted(set(Ts))
    return Ls, Ts


def _design_row(L_var: float, T: float, degree: int) -> List[float]:
    row = []
    for d in range(degree + 1):
        row.append(L_var * T**d)
    for d in range(degree + 1):
        row.append(float(T**d))
    return row


def fit_and_eval(points: List[Tuple[int, int, float]], L_full: int, T_full: int,
                 L_off: int, degree: int) -> float:
    """points: [(num_layers, T, value)]; L_off = layers absorbed in the intercept."""
    # degenerate T spread: drop degree to what the points support
    n_t = len({t for _, t, _ in points})
    degree = min(degree, n_t - 1)
    A = np.array([_design_row(L - L_off, T, degree) for L, T, _ in points])
    y = np.array([v for _, _, v in points])
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = float(np.dot(_design_row(L_full - L_off, T_full, degree), coef))
    return max(pred, 0.0)


# --------------------------------------------------------------------- measurement
def measure(arch: str, shape_name: str, num_layers: int, seq_len: int,
            rules_override: Optional[str] = None, grad_accum: int = 1,
            opts_override=None) -> Dict[str, float]:
    """One unrolled analysis lower+compile; returns per-device cost terms."""
    from repro.launch.dryrun import build_cell, parse_collectives

    lowered, meta = build_cell(
        arch, shape_name, multi_pod=False, num_layers=num_layers,
        seq_len=seq_len, unroll=True, rules_override=rules_override,
        grad_accum=grad_accum, opts_override=opts_override,
    )
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "link_bytes": float(coll["link_bytes"]),
    }


@dataclasses.dataclass
class RooflineResult:
    arch: str
    shape: str
    rules: str
    chips: int
    # corrected per-device totals
    flops_dev: float
    bytes_dev: float
    link_bytes_dev: float
    host_dma_bytes_dev: float
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    t_hostdma: float
    bottleneck: str
    model_flops: float
    useful_ratio: float      # MODEL_FLOPS / (flops_dev * chips)
    roofline_fraction: float  # useful-compute-time / bottleneck-time
    fit_points: int
    seconds: float
    label: str = "baseline"


def analyze_cell(arch: str, shape_name: str,
                 rules_override: Optional[str] = None,
                 opts_override=None, grad_accum: int = 1,
                 label: str = "baseline",
                 chips: int = 256) -> Optional[RooflineResult]:
    from repro.models.transformer import model_flops

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, _reason = cfg.supports_shape(shape)
    if not ok:
        return None
    t0 = time.time()
    Ls, Ts = analysis_points(cfg, shape)
    degree = cost_degree(cfg, shape)
    pts = []
    for L in Ls:
        for T in Ts:
            m = measure(arch, shape_name, L, T, rules_override, grad_accum,
                        opts_override)
            pts.append((L, T, m))
    L_off = cfg.moe_first_dense
    terms = {}
    for key in ("flops", "bytes", "link_bytes"):
        terms[key] = fit_and_eval(
            [(L, T, m[key]) for L, T, m in pts], cfg.num_layers, shape.seq_len,
            L_off, degree,
        )

    # host-DMA ledger from the offload manifest (CPU cannot place host buffers)
    from repro.launch.dryrun import default_hp
    from repro.launch.specs import offload_manifest

    man = offload_manifest(cfg, default_hp(cfg))
    host_bytes_dev = man.dma_bytes_per_step() / chips if shape.kind == "train" else 0.0

    t_compute = terms["flops"] / V5E.peak_flops_bf16
    t_memory = terms["bytes"] / V5E.hbm_bandwidth
    t_collective = terms["link_bytes"] / V5E.ici_link_bandwidth
    t_hostdma = host_bytes_dev / V5E.host_link_bandwidth
    named = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective, "host_dma": t_hostdma}
    bottleneck = max(named, key=named.get)

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(cfg, tokens, "train")
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(cfg, tokens, "inference")
    else:
        mf = model_flops(cfg, shape.global_batch, "inference")

    total_hlo = terms["flops"] * chips
    useful_ratio = mf / total_hlo if total_hlo else 0.0
    t_useful = mf / chips / V5E.peak_flops_bf16
    frac = t_useful / max(max(named.values()), 1e-30)

    return RooflineResult(
        arch=arch, shape=shape_name,
        rules=rules_override or "", chips=chips,
        flops_dev=terms["flops"], bytes_dev=terms["bytes"],
        link_bytes_dev=terms["link_bytes"], host_dma_bytes_dev=host_bytes_dev,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_collective,
        t_hostdma=t_hostdma, bottleneck=bottleneck,
        model_flops=mf, useful_ratio=useful_ratio, roofline_fraction=frac,
        fit_points=len(pts), seconds=time.time() - t0, label=label,
    )


def cell_path(arch: str, shape_name: str, label: str = "baseline") -> pathlib.Path:
    return OUT_DIR / f"{arch}__{shape_name}__{label}.json"


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape_name in shapes:
            path = cell_path(arch, shape_name)
            if path.exists() and not args.force:
                print(f"[roofline] {arch} x {shape_name}: cached")
                continue
            res = analyze_cell(arch, shape_name)
            if res is None:
                print(f"[roofline] {arch} x {shape_name}: skip")
                continue
            path.write_text(json.dumps(dataclasses.asdict(res), indent=1))
            print(f"[roofline] {arch} x {shape_name}: {res.bottleneck}-bound "
                  f"frac={res.roofline_fraction:.3f} useful={res.useful_ratio:.3f} "
                  f"({res.seconds:.0f}s)")


if __name__ == "__main__":
    main()
